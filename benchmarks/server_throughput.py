"""Multi-session server throughput: sessions x RTF curve, single or sharded.

Default mode sweeps the number of concurrent streams served by ONE
fixed-capacity ``SessionPool`` (one compiled batched hop step per backend,
no recompilation across sweep points — the server's core scaling property)
and reports, per point:

- aggregate RTF: total compute seconds per total audio seconds (< 1 means the
  whole batch is served in real time) and rt_capacity = 1 / aggregate RTF,
- per-session RTF (mean),
- pool step latency p50/p95 in ms against the 16 ms hop budget.

Three sweep axes compare the serving configurations this benchmark exists
for:

- ``--backend xla,pallas`` — the training graph lowered through XLA vs the
  deploy-compiled fused graph (``repro.serve.deploy``: BN folded, Pallas
  kernels). Off-TPU the Pallas kernels run in INTERPRET mode — correctness
  smoke, not a speed claim; sweep it on TPU for real numbers.
- ``--buffering single,double`` — classic serial pump vs double-buffered
  ingestion (``SessionPool(inflight=2)``: host ring drain overlaps the
  in-flight device step).
- ``--hops-per-step 1,4,8`` — multi-hop fused dispatch depth
  (``SessionPool(hops_per_step=K)``): how many hops each backlogged session
  drains per device call. K>1 amortizes the per-hop host->device->host +
  Python dispatch cost; the ``comparisons`` block reports the aggregate-RTF
  ratio of each K against K=1 (``hops{K}_vs_hops1``) — the speedup the
  fused path buys on this host.
- ``--transport inproc,socket`` — direct pool calls vs the cross-process
  fabric: socket points serve every session through a localhost
  ``StreamingGateway`` (real TCP, framed protocol, the gateway's own pump
  loop over a 1-shard ``ShardedSessionPool``), so ``socket_vs_inproc`` is
  the measured price of the network front door. Sessions-sweep mode only.
- ``--durability off,on`` — the crash-recovery tax: ``on`` points serve
  through a pool wired to a ``DurabilityManager`` (write-ahead hop journal
  on every feed, ticket snapshot every ``--snapshot-every`` hops), so
  ``durability_vs_off`` is the measured RTF overhead of crash-proof
  sessions. Durable points additionally record the raw I/O the manager
  performed (``journal_records`` / ``journal_bytes`` / ``snapshots`` /
  ``snapshot_bytes``). Sessions-sweep mode, inproc transport only.
- ``--guards off,on`` — the fault-containment tax: ``on`` points serve
  through a pool with the post-collect finite guard armed (every collected
  hop's output and carried state checked for NaN/Inf before release; on
  the socket transport the 1-shard router additionally runs its circuit
  breaker + step watchdog), so ``guards_vs_off`` is the measured RTF
  overhead of the containment plane — the acceptance bar is <= 5% on a CPU
  smoke run. Sessions-sweep mode only.

``--ramp`` instead drives an **elastic** pool (``ElasticSessionPool``,
``--tiers`` capacity ladder) through a session ramp that climbs past at
least two tier boundaries and back down: at every target occupancy it feeds
all live sessions and pumps, while one pilot session streams continuously
across the whole ramp (so a dropped or corrupted stream is detected, not
averaged away). Each point records the current tier plus cumulative
grow/shrink counts; the JSON artifact additionally gets a ``resizes``
summary (counts + migration-pause ms) per backend — the numbers the
ROADMAP's elastic-capacity item asks for.

``--adaptive`` instead runs the **bursty-trace scheduler sweep**: the same
seeded ragged burst arrivals (0..2*k_max hops per session per round, ~30%
silent rounds) are served three ways — a static K=1 pool, a static K=k_max
pool, and an adaptive pool (``AdaptiveScheduler`` picking per-dispatch K
from measured backlog, device ingestion ring) — and the JSON gains
``adaptive_vs_hops1`` / ``adaptive_vs_hops{k_max}`` scorecards (mean
aggregate-RTF ratio and mean per-pump p50 ratio, matched on backend and
session count). The claim under test: adaptive p50 pump latency tracks the
K=1 fast path while bursty throughput tracks the deep static pool.

``--shards N`` instead sweeps SHARD COUNT at full per-shard load through
``ShardedSessionPool`` (one pool per device, overlapped ``pump_all``). If
capacity scales linearly with devices, rt_capacity grows ~linearly in the
shard sweep (faked CPU devices share one core: expect a flat curve there).
On a CPU-only host, fake devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python benchmarks/server_throughput.py --shards 4

Results go to BOTH stdout (CSV via benchmarks.common.emit, human-scannable)
and a machine-readable ``BENCH_server_throughput.json`` (``--json`` to move
it): full config, every sweep point, and cross-config RTF ratios — the
artifact CI and regression tooling consume.

``--smoke`` shrinks everything (capacity 2, 0.25 s audio, 1-2 sessions) so
the pallas/interpret path finishes in seconds — the CI guard that keeps the
deploy path from rotting.

Run:  PYTHONPATH=src python benchmarks/server_throughput.py [--capacity N]
          [--seconds S] [--quant] [--shards N] [--backend xla,pallas]
          [--buffering single,double] [--hops-per-step 1,4,8] [--ramp]
          [--adaptive] [--transport inproc,socket] [--durability off,on]
          [--guards off,on] [--snapshot-every N] [--tiers 4,16,64]
          [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from common import emit  # noqa: E402

from repro.audio.synthetic import batch_for_step  # noqa: E402
from repro.core.quant import FP10  # noqa: E402
from repro.launch.serve import parse_tiers, reduced_cfg  # noqa: E402
from repro.models import tftnn as tft  # noqa: E402
from repro.serve import (  # noqa: E402
    DurabilityManager,
    ElasticSessionPool,
    PoolFullError,
    SessionPool,
    ShardedSessionPool,
    make_stream_hop,
    scheduler_for_pool,
)


def bench_cfg() -> tft.TFTConfig:
    """Paper front end (512/128 @ 8 kHz), reduced trunk for CPU wall-clock —
    the same profile the launcher's --reduced flag uses."""
    return reduced_cfg(tft.tftnn_config())


def run_point(pool: SessionPool, n_sessions: int, audio: np.ndarray) -> dict:
    sessions = [pool.attach() for _ in range(n_sessions)]
    pool.step_seconds.clear()
    for i, s in enumerate(sessions):
        pool.feed(s, audio[i % audio.shape[0]])
    # wall-clock, not summed step latencies: under double buffering (inflight
    # > 1) a step's dispatch->ready time includes pipeline queueing, so the
    # sum double-counts overlapped work — wall time compares modes honestly.
    t0 = time.perf_counter()
    pool.pump()
    wall = time.perf_counter() - t0
    hop, sr = pool.cfg.hop, pool.sample_rate
    audio_sec = sum(s.stats.hops for s in sessions) * hop / sr
    rtfs = [s.stats.rtf(sr, hop) for s in sessions]
    pct = pool.latency_percentiles()
    for s in sessions:
        pool.detach(s)
    rtf = wall / audio_sec
    return {
        "sessions": n_sessions,
        "aggregate_rtf": rtf,
        "rt_capacity": 1.0 / rtf if rtf > 0 else float("inf"),
        "mean_session_rtf": float(np.mean(rtfs)),
        "p50_ms": pct[50],
        "p95_ms": pct[95],
    }




def run_bursty_point(pool: SessionPool, n_sessions: int, audio: np.ndarray,
                     *, rounds: int, k_max: int, seed: int = 1234,
                     sched=None) -> dict:
    """One bursty-trace point: seeded ragged bursts, per-pump latency p50.

    Every round feeds each session an independent burst of 0..2*k_max hops
    (~30% of rounds are silent for a session) and pumps — with the adaptive
    scheduler when ``sched`` is given, the static full-K pump otherwise.
    The SAME ``seed`` drives every configuration, so adaptive and static
    points see identical arrival sequences and the ratios compare schedules,
    not workloads. p50/p95 are over per-PUMP wall times (what a caller's
    event loop blocks on), aggregate RTF over the whole trace.
    """
    import random

    rnd = random.Random(seed)
    hop, sr = pool.cfg.hop, pool.sample_rate
    sessions = [pool.attach() for _ in range(n_sessions)]
    pool.step_seconds.clear()
    pump_walls = []
    wall = 0.0
    for _ in range(rounds):
        for i, s in enumerate(sessions):
            if rnd.random() < 0.3:
                continue  # silent round for this session
            hops = rnd.randint(1, 2 * k_max)
            pool.feed(s, audio[i % audio.shape[0]][: hops * hop])
        t0 = time.perf_counter()
        pool.pump(sched) if sched is not None else pool.pump()
        dt = time.perf_counter() - t0
        pump_walls.append(dt)
        wall += dt
    audio_sec = sum(s.stats.hops for s in sessions) * hop / sr
    for s in sessions:
        pool.detach(s)
    rtf = wall / audio_sec if audio_sec else float("inf")
    walls_ms = np.asarray(pump_walls) * 1e3
    point = {
        "sessions": n_sessions,
        "aggregate_rtf": rtf,
        "rt_capacity": 1.0 / rtf if rtf > 0 else float("inf"),
        "p50_pump_ms": float(np.percentile(walls_ms, 50)),
        "p95_pump_ms": float(np.percentile(walls_ms, 95)),
        "rounds": rounds,
    }
    if sched is not None:
        stats = sched.stats()
        point["k_mean"] = stats["k_mean"]
        point["k_max_seen"] = stats["k_max_seen"]
    return point


def run_socket_point(gw, n_sessions: int, audio: np.ndarray) -> dict:
    """One sessions-sweep point across the fabric: every session is a real
    ``GatewayClient`` TCP connection to the gateway's localhost socket.

    Same accounting shape as ``run_point`` so the ``socket_vs_inproc``
    ratio compares like with like; wall-clock covers feed-to-last-sample
    (the gateway's pump loop serves continuously, so readback latency is
    part of what the transport costs).
    """
    from repro.serve.gateway import GatewayClient

    hop, sr = gw.pool.cfg.hop, gw.pool.sample_rate
    expect = (audio.shape[1] // hop) * hop
    host, port = gw.address
    gw.call(lambda p: [q.step_seconds.clear() for q in p._pools])
    clients = [GatewayClient(host, port) for _ in range(n_sessions)]
    try:
        for c in clients:
            c.attach()
        t0 = time.perf_counter()
        for i, c in enumerate(clients):
            c.feed(audio[i % audio.shape[0]])
        outs = [c.read_until(expect, timeout=300) for c in clients]
        wall = time.perf_counter() - t0
    finally:
        for c in clients:
            c.close()
    assert all(o.size == expect for o in outs)
    pct = gw.call(lambda p: p._pools[0].latency_percentiles())
    audio_sec = n_sessions * expect / sr
    rtf = wall / audio_sec
    return {
        "sessions": n_sessions,
        "aggregate_rtf": rtf,
        "rt_capacity": 1.0 / rtf if rtf > 0 else float("inf"),
        "mean_session_rtf": rtf,
        "p50_ms": pct[50],
        "p95_ms": pct[95],
    }


def run_sharded_point(params, cfg, n_shards: int, per_shard: int,
                      audio: np.ndarray, quant, backend: str,
                      hops_per_step: int, step_cache: dict) -> dict:
    """One shard-sweep point: fill n_shards x per_shard sessions, pump_all.

    ``step_cache`` is shared across sweep points so each device compiles the
    hop step once for the whole sweep (cfg/capacity/quant/backend/
    hops_per_step constant)."""
    pool = ShardedSessionPool(params, cfg, per_shard, shards=n_shards,
                              quant=quant, backend=backend,
                              hops_per_step=hops_per_step,
                              step_cache=step_cache)
    n_sessions = n_shards * per_shard
    handles = [pool.attach(f"bench-{i}", rebalance_on_full=True)
               for i in range(n_sessions)]
    # warm up each shard's one compilation outside the timed window
    for i, h in enumerate(handles):
        pool.feed(h, audio[i % audio.shape[0]][: 2 * cfg.hop])
    pool.pump_all()
    warm_hops = sum(h.stats.hops for h in handles)  # exclude from timed audio
    for i, h in enumerate(handles):
        pool.feed(h, audio[i % audio.shape[0]])
    t0 = time.perf_counter()
    pool.pump_all()
    wall = time.perf_counter() - t0
    timed_hops = sum(h.stats.hops for h in handles) - warm_hops
    audio_sec = timed_hops * cfg.hop / pool.sample_rate
    rtf = wall / audio_sec
    for h in handles:
        pool.detach(h)
    return {
        "shards": n_shards,
        "sessions": n_sessions,
        "aggregate_rtf": rtf,
        # sustainable real-time streams: total audio seconds / wall second.
        # rtf's denominator already sums audio over every session, so this is
        # 1/rtf — NOT sessions/rtf, which would double-count session count.
        "rt_capacity": 1.0 / rtf if rtf > 0 else float("inf"),
        "wall_s": wall,
    }


def _ramp_targets(tiers: tuple) -> list:
    """Occupancy targets that fill each tier, cross its boundary (grow), then
    descend below the shrink watermarks (shrink) — every grow AND shrink edge
    of the ladder is exercised once."""
    up = []
    for lo in tiers[:-1]:
        up.extend([lo, lo + 1])  # fill the tier, then force a grow
    up.append(min(tiers[-1], tiers[-2] + 2))
    # descend to half of each lower tier: under the default shrink_fraction
    # watermark, so the lazy shrinker steps back down the ladder
    down = [max(1, t // 2) for t in reversed(tiers[:-1])]
    return up + down + [1]


def run_ramp(params, cfg, tiers: tuple, audio: np.ndarray, quant,
             backend: str, buffering: str, hops_per_step: int = 1,
             step_fn=None) -> tuple:
    """Drive an ElasticSessionPool through the ramp; returns (points, summary).

    One **pilot** session streams continuously across every target (attached
    first, never detached): its hop count must equal the total audio it was
    fed, so a session dropped or corrupted by a resize fails the run instead
    of vanishing into an average. ``shrink_patience=1`` makes the down-ramp
    shrink on the next pump instead of waiting out the serving-loop
    hysteresis; ``prewarm=True`` compiles every tier up front so per-tier RTF
    measures serving, not jit.
    """
    pool = ElasticSessionPool(
        params, cfg, tiers, quant=quant, backend=backend,
        inflight=2 if buffering == "double" else 1,
        hops_per_step=hops_per_step, step_fn=step_fn,
        shrink_patience=1, prewarm=True,
    )
    hop, sr = cfg.hop, pool.sample_rate
    pilot = pool.attach()
    handles = []
    points = []
    pilot_samples = 0
    dropped = 0  # attaches the elastic pool refused (should never happen:
    # every ramp target fits under the top tier)
    for target in _ramp_targets(tiers):
        while pool.num_active < target:
            try:
                handles.append(pool.attach())
            except PoolFullError:
                dropped += 1
                break
        while pool.num_active > target and handles:
            pool.detach(handles.pop())
        live = [pilot] + handles
        for i, h in enumerate(live):
            pool.feed(h, audio[i % audio.shape[0]])
        t0 = time.perf_counter()
        pool.pump()
        wall = time.perf_counter() - t0
        pilot_samples += pool.read(pilot).size  # pilot continuity, and keeps _out flat
        audio_sec = len(live) * (audio.shape[1] // hop) * hop / sr
        rtf = wall / audio_sec
        points.append({
            "sessions": target,
            "tier": pool.capacity,
            "aggregate_rtf": rtf,
            "rt_capacity": 1.0 / rtf if rtf > 0 else float("inf"),
            "grows": pool.grow_count,
            "shrinks": pool.shrink_count,
            "wall_s": wall,
        })
    for _ in range(len(tiers)):
        pool.pump()  # idle heartbeats: let the lazy shrinker settle
    expected = pilot.stats.samples_in // hop * hop
    if pilot_samples != expected or pilot.stats.hops * hop != expected:
        raise SystemExit(
            f"pilot stream lost audio across the ramp: read {pilot_samples} "
            f"of {expected} samples ({pilot.stats.hops} hops)"
        )
    pauses = np.asarray(pool.resize_seconds) * 1e3 if pool.resize_seconds else np.zeros(1)
    summary = {
        "backend": backend,
        "buffering": buffering,
        "hops_per_step": hops_per_step,
        "tiers": list(tiers),
        "grows": pool.grow_count,
        "shrinks": pool.shrink_count,
        "resize_log": [list(t) for t in pool.resize_log],
        "mean_pause_ms": float(pauses.mean()),
        "max_pause_ms": float(pauses.max()),
        "final_tier": pool.capacity,
        "dropped_sessions": dropped,  # measured: refused attaches (pilot
        # integrity is enforced separately by the SystemExit check above)
        "pilot_hops": pilot.stats.hops,
    }
    return points, summary


def _shard_sweep(n_max: int) -> list:
    s, out = 1, []
    while s < n_max:
        out.append(s)
        s *= 2
    out.append(n_max)
    return sorted(set(out))


def _csv_list(raw: str, allowed: tuple) -> list:
    vals = [v.strip() for v in raw.split(",") if v.strip()]
    for v in vals:
        if v not in allowed:
            raise SystemExit(f"unknown value {v!r}: expected one of {allowed}")
    if not vals:
        raise SystemExit(f"need at least one of {allowed}")
    return vals


def _csv_ints(raw: str, what: str) -> list:
    try:
        vals = [int(v) for v in raw.split(",") if v.strip()]
    except ValueError:
        raise SystemExit(f"{what} must be a comma list of ints, got {raw!r}")
    if not vals or any(v < 1 for v in vals):
        raise SystemExit(f"{what} needs one or more ints >= 1, got {raw!r}")
    return sorted(set(vals))


_SWEEP_AXES = ("backend", "buffering", "hops_per_step", "transport",
               "scheduler", "durability", "guards")


def _ratio(points: list, key: str, a: str, b: str) -> dict:
    """Mean aggregate-RTF ratio b/a between sweep points that match on every
    OTHER axis (mode, sessions, shards, and the non-compared config axis) —
    e.g. pallas/single is only ever divided by xla/single, never xla/double."""
    others = tuple(ax for ax in _SWEEP_AXES if ax != key)
    def mk(p):
        return (p["mode"], p.get("sessions"), p.get("shards"),
                *(p.get(ax) for ax in others))
    pa = {mk(p): p["aggregate_rtf"] for p in points if p[key] == a}
    ratios = [p["aggregate_rtf"] / pa[mk(p)]
              for p in points if p[key] == b and mk(p) in pa]
    return {"num_points": len(ratios),
            "mean_rtf_ratio": float(np.mean(ratios)) if ratios else None}


def _adaptive_ratio(points: list, static_k: int) -> dict:
    """Adaptive-vs-static ratios on the bursty sweep, matched on
    (backend, sessions): mean aggregate-RTF ratio AND mean per-pump p50
    ratio of the adaptive points against the static K=``static_k`` points
    (< 1.0 = the adaptive schedule is cheaper on that metric)."""
    base = {
        (p["backend"], p["sessions"]): p
        for p in points
        if p.get("mode") == "bursty" and p["scheduler"] == "static"
        and p["hops_per_step"] == static_k
    }
    rtf, p50 = [], []
    for p in points:
        if p.get("mode") != "bursty" or p["scheduler"] != "adaptive":
            continue
        ref = base.get((p["backend"], p["sessions"]))
        if ref is None:
            continue
        rtf.append(p["aggregate_rtf"] / ref["aggregate_rtf"])
        p50.append(p["p50_pump_ms"] / ref["p50_pump_ms"])
    return {
        "num_points": len(rtf),
        "mean_rtf_ratio": float(np.mean(rtf)) if rtf else None,
        "mean_p50_ratio": float(np.mean(p50)) if p50 else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Multi-session server throughput: sessions x RTF "
        "(single pool) or shard-count sweep (--shards, one pool per device), "
        "with xla-vs-pallas and single-vs-double-buffered comparisons; "
        "machine-readable results in BENCH_server_throughput.json."
    )
    ap.add_argument("--capacity", type=int, default=16,
                    help="slots compiled into each pool (per shard when --shards > 0)")
    ap.add_argument("--seconds", type=float, default=1.0,
                    help="seconds of audio fed to each session")
    ap.add_argument("--quant", action="store_true",
                    help="serve on the paper's FP10 deployment grid")
    ap.add_argument("--backend", default="xla",
                    help="comma list of hop backends to sweep: xla,pallas "
                    "(pallas = deploy-compiled fused graph; interpret mode off-TPU)")
    ap.add_argument("--buffering", default="single",
                    help="comma list of ingestion modes to sweep: single,double "
                    "(double = inflight=2 host/device overlap); single-pool mode only")
    ap.add_argument("--hops-per-step", default="1",
                    help="comma list of fused-dispatch depths to sweep, e.g. "
                    "1,4,8 — K>1 drains up to K hops per session per device "
                    "call (scan-batched step, bit-identical to K=1); the "
                    "JSON gains a hops{K}_vs_hops1 RTF ratio per K")
    ap.add_argument("--transport", default="inproc",
                    help="comma list of serving transports to sweep: "
                    "inproc,socket — socket serves each point through a "
                    "localhost StreamingGateway (real TCP clients, framed "
                    "chunk protocol); sessions-sweep mode only")
    ap.add_argument("--durability", default="off",
                    help="comma list of crash-recovery modes to sweep: "
                    "off,on — on serves through a pool wired to a "
                    "DurabilityManager (write-ahead hop journal + periodic "
                    "ticket snapshots in a temp dir), recording the RTF tax "
                    "and the raw journal/snapshot I/O per point; "
                    "sessions-sweep mode, inproc transport only")
    ap.add_argument("--snapshot-every", type=int, default=16,
                    help="snapshot cadence in hops for --durability on points")
    ap.add_argument("--guards", default="off",
                    help="comma list of fault-containment modes to sweep: "
                    "off,on — on serves through a pool with the post-collect "
                    "finite guard armed (and, on the socket transport, shard "
                    "circuit breakers + step watchdog), recording the RTF "
                    "tax of the containment plane; the JSON gains a "
                    "guards_vs_off ratio; sessions-sweep mode only")
    ap.add_argument("--adaptive", action="store_true",
                    help="bursty-trace sweep comparing the self-tuning "
                    "scheduler (AdaptiveScheduler + device ingestion ring) "
                    "against static K=1 and static K=k_max pools on "
                    "IDENTICAL seeded burst arrivals; the JSON gains "
                    "adaptive_vs_hops1 / adaptive_vs_hops{k_max} ratios "
                    "(aggregate RTF and per-pump p50)")
    ap.add_argument("--shards", type=int, default=0,
                    help="sweep ShardedSessionPool from 1 up to N shards at full "
                    "per-shard load (0 = single-pool sessions sweep); fake CPU "
                    "devices with XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--ramp", action="store_true",
                    help="elastic ramp workload: sweep sessions up past the "
                    "--tiers boundaries and back down through an "
                    "ElasticSessionPool, recording tier, RTF, resize counts "
                    "and migration pause per point")
    ap.add_argument("--tiers", default="4,16,64",
                    help="--ramp capacity ladder (comma list, strictly "
                    "increasing, each >= 2; needs >= 2 tiers)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="best-of-N repeats per single-pool sweep point, "
                    "interleaved round-robin across configs (min wall-clock "
                    "wins, as in timeit) — noisy scheduler phases hit every "
                    "config equally instead of skewing the comparison "
                    "ratios; --smoke raises it to >= 5 when sweeping "
                    "multiple --hops-per-step values")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (capacity<=2, ~0.26s audio, 1-2 "
                    "sessions; best-of-5 points when sweeping "
                    "--hops-per-step) so the pallas/interpret path stays "
                    "fast")
    ap.add_argument("--json", default="BENCH_server_throughput.json",
                    help="where to write the machine-readable results")
    args = ap.parse_args()

    backends = _csv_list(args.backend, ("xla", "pallas"))
    bufferings = _csv_list(args.buffering, ("single", "double"))
    hops_sweep = _csv_ints(args.hops_per_step, "--hops-per-step")
    transports = _csv_list(args.transport, ("inproc", "socket"))
    durabilities = _csv_list(args.durability, ("off", "on"))
    guard_modes = _csv_list(args.guards, ("off", "on"))
    if "socket" in transports and (args.ramp or args.shards > 0):
        raise SystemExit("--transport socket only sweeps in sessions mode")
    if "on" in durabilities and (args.ramp or args.shards > 0 or args.adaptive):
        raise SystemExit("--durability on only sweeps in sessions mode")
    if "on" in guard_modes and (args.ramp or args.shards > 0 or args.adaptive):
        raise SystemExit("--guards on only sweeps in sessions mode")
    if "on" in durabilities and "socket" in transports:
        raise SystemExit("--durability on sweeps the inproc transport only")
    if args.snapshot_every < 1:
        raise SystemExit("--snapshot-every must be >= 1")
    if args.adaptive and (args.ramp or args.shards > 0):
        raise SystemExit("--adaptive is its own mode: drop --ramp/--shards")
    if args.adaptive and "socket" in transports:
        raise SystemExit("--adaptive sweeps in-process pools only")
    # the adaptive sweep's static reference depths: K=1 and the ceiling
    adaptive_kmax = max(hops_sweep) if max(hops_sweep) > 1 else 8
    if args.repeats < 1:
        raise SystemExit("--repeats must be >= 1")
    if args.smoke:
        args.capacity = min(args.capacity, 2)
        # 0.26 s = 16 hops: a whole number of K=8 fused dispatches, so the
        # hops sweep measures amortization rather than a ragged final lane
        args.seconds = min(args.seconds, 0.26)
        if len(hops_sweep) > 1:
            # only the hops{K}_vs_hops1 ratios need best-of-N stability;
            # don't quintuple the pallas-interpret smoke for other sweeps
            args.repeats = max(args.repeats, 5)
        if args.adaptive:
            args.repeats = max(args.repeats, 3)
        if len(guard_modes) > 1:
            # the guards_vs_off ratio carries a <= 5% overhead contract:
            # best-of-N keeps scheduler noise out of a few-percent comparison
            args.repeats = max(args.repeats, 5)
        if args.ramp and args.tiers == "4,16,64":
            args.tiers = "2,4,8"  # CI-sized ladder, still two boundaries
    tiers = parse_tiers(args.tiers)
    if args.ramp and len(tiers) < 2:
        raise SystemExit(f"--ramp needs >= 2 tiers, got {tiers}")

    cfg = bench_cfg()
    params = tft.init_tft(jax.random.PRNGKey(0), cfg)
    quant = FP10 if args.quant else None

    sample_rate = 8000
    # at least one whole hop, else nothing is ever enhanced (div-by-zero)
    samples = max(cfg.hop, int(args.seconds * sample_rate) // cfg.hop * cfg.hop)
    noisy, _ = batch_for_step(1, 0, batch=4, num_samples=samples)
    audio = np.asarray(noisy, np.float32)
    budget_ms = cfg.hop / sample_rate * 1e3

    result = {
        "benchmark": "server_throughput",
        "config": {
            "capacity": args.capacity,
            "seconds_per_session": args.seconds,
            "quant": "fp10" if args.quant else "fp32",
            "backends": backends,
            "bufferings": bufferings,
            "hops_per_step": hops_sweep,
            "transports": transports,
            "durability": durabilities,
            "guards": guard_modes,
            "snapshot_every": args.snapshot_every if "on" in durabilities else None,
            "shards_max": args.shards,
            "ramp": args.ramp,
            "adaptive": args.adaptive,
            "adaptive_k_max": adaptive_kmax if args.adaptive else None,
            "tiers": list(tiers) if args.ramp else None,
            "smoke": args.smoke,
            "hop_budget_ms": budget_ms,
            "devices": len(jax.local_devices()),
            "jax_backend": jax.default_backend(),
        },
        "points": [],
    }
    points = result["points"]
    print("name,us_per_call,derived")

    if args.ramp:
        print(f"# elastic ramp over tiers={tiers}, audio/session/point="
              f"{args.seconds}s, backends={backends}, bufferings={bufferings}, "
              f"hops_per_step={hops_sweep}, "
              f"quant={'fp10' if args.quant else 'fp32'}")
        result["resizes"] = []
        for backend in backends:
            for hps in hops_sweep:
                # buffering is host-side only: share one compiled step per
                # (backend, K) so the second ramp's prewarm hits the jit cache
                step = make_stream_hop(params, cfg, quant=quant,
                                       backend=backend, max_hops_per_step=hps)
                for buffering in bufferings:
                    ramp_points, summary = run_ramp(
                        params, cfg, tiers, audio, quant, backend, buffering,
                        hops_per_step=hps, step_fn=step)
                    for r in ramp_points:
                        r.update(mode="ramp", backend=backend,
                                 buffering=buffering, hops_per_step=hps,
                                 transport="inproc")
                        points.append(r)
                        emit(
                            f"backend={backend} buffering={buffering} "
                            f"hops={hps} ramp sessions={r['sessions']}",
                            r["wall_s"] * 1e6,
                            f"tier={r['tier']} aggregate_rtf={r['aggregate_rtf']:.3f} "
                            f"grows={r['grows']} shrinks={r['shrinks']}",
                        )
                    result["resizes"].append(summary)
                    print(f"# resizes[{backend}/{buffering}/hops={hps}]: "
                          f"grows={summary['grows']} shrinks={summary['shrinks']} "
                          f"max_pause={summary['max_pause_ms']:.2f}ms "
                          f"dropped={summary['dropped_sessions']}")
    elif args.adaptive:
        kmax = adaptive_kmax
        rounds = 6 if args.smoke else 16
        sweep = [n for n in (1, 2, 4, 8, 16) if n <= args.capacity]
        print(f"# bursty adaptive sweep: k_max={kmax}, rounds={rounds}, "
              f"backends={backends}, repeats={args.repeats}, "
              f"quant={'fp10' if args.quant else 'fp32'}")
        variants = [("static", 1), ("static", kmax), ("adaptive", kmax)]
        combos = []
        for backend in backends:
            steps: dict = {}  # ONE step cache per backend: static keys are
            # (k, None), adaptive ring keys (k, 2*kmax) — shared across
            # variants and every interleaved repeat, no recompiles mid-sweep
            for label, k in variants:
                ring = 2 * kmax if label == "adaptive" else None
                pool = SessionPool(
                    params, cfg, capacity=args.capacity, quant=quant,
                    backend=backend, hops_per_step=k, ingest_ring=ring,
                    step_fns=steps,
                )
                # warm every lane depth this variant can pick OUTSIDE the
                # timed points (the adaptive pool compiles its whole ladder)
                ladder = (
                    scheduler_for_pool(k).config.k_ladder
                    if label == "adaptive" else (k,)
                )
                w = pool.attach()
                for kk in ladder:
                    pool.feed(w, audio[0][: kk * cfg.hop])
                    pool.pump(scheduler_for_pool(k)
                              if label == "adaptive" else None)
                pool.detach(w)
                combos.append((backend, label, k, pool))
        # interleaved best-of-N, exactly like the sessions sweep: every
        # variant sees the same seeded arrival trace on every repeat
        best = {}
        for _ in range(args.repeats):
            for backend, label, k, pool in combos:
                for n in sweep:
                    sched = (scheduler_for_pool(k)
                             if label == "adaptive" else None)
                    r = run_bursty_point(pool, n, audio, rounds=rounds,
                                         k_max=kmax, sched=sched)
                    key = (backend, label, k, n)
                    if key not in best or r["aggregate_rtf"] < best[key]["aggregate_rtf"]:
                        best[key] = r
        for backend, label, k, _pool in combos:
            for n in sweep:
                r = best[(backend, label, k, n)]
                r.update(mode="bursty", backend=backend, buffering="single",
                         hops_per_step=k, transport="inproc", scheduler=label)
                points.append(r)
                emit(
                    f"backend={backend} scheduler={label} hops={k} "
                    f"sessions={n}",
                    r["p50_pump_ms"] * 1e3,
                    f"aggregate_rtf={r['aggregate_rtf']:.3f} "
                    f"p95_pump_ms={r['p95_pump_ms']:.2f}"
                    + (f" k_mean={r['k_mean']:.2f}"
                       f" k_max_seen={r['k_max_seen']}"
                       if label == "adaptive" else ""),
                )
    elif args.shards > 0:
        print(f"# shard sweep up to {args.shards}, capacity/shard={args.capacity}, "
              f"audio/session={args.seconds}s, backends={backends}, "
              f"hops_per_step={hops_sweep}, "
              f"quant={'fp10' if args.quant else 'fp32'}")
        for backend in backends:
            for hps in hops_sweep:
                step_cache = {}  # one compilation per device across the sweep
                for s in _shard_sweep(args.shards):
                    r = run_sharded_point(params, cfg, s, args.capacity, audio,
                                          quant, backend, hps, step_cache)
                    r.update(mode="shards", backend=backend,
                             buffering="single", hops_per_step=hps,
                             transport="inproc")
                    points.append(r)
                    # space-separated name: emit() quotes nothing, so a comma
                    # here would break the 3-column CSV contract
                    emit(
                        f"backend={backend} hops={hps} shards={s}",
                        r["wall_s"] * 1e6,
                        f"sessions={r['sessions']} aggregate_rtf={r['aggregate_rtf']:.3f} "
                        f"rt_capacity={r['rt_capacity']:.1f} "
                        f"real_time={'yes' if r['aggregate_rtf'] < 1 else 'no'}",
                    )
    else:
        print(f"# capacity={args.capacity} audio/session={args.seconds}s "
              f"hop_budget={budget_ms:.1f}ms backends={backends} "
              f"bufferings={bufferings} hops_per_step={hops_sweep} "
              f"transports={transports} "
              f"quant={'fp10' if args.quant else 'fp32'}")
        sweep = [n for n in (1, 2, 4, 8, 16) if n <= args.capacity]
        combos = []
        gateways = []
        tmpdirs = []
        for backend in backends:
            for hps in hops_sweep:
                # buffering changes only host-side pipelining, not the
                # compiled step — compile once per (backend, K) and share it
                step = make_stream_hop(params, cfg, quant=quant,
                                       backend=backend, max_hops_per_step=hps)
                for buffering in bufferings:
                  for transport in transports:
                    for durability in durabilities:
                        for guard in guard_modes:
                            inflight = 2 if buffering == "double" else 1
                            armed = guard == "on"
                            manager = None
                            if durability == "on":
                                # temp-dir journal/snapshot store; detach at
                                # the end of each point forgets the files, so
                                # repeats never replay a prior point's state
                                tmp = tempfile.TemporaryDirectory(
                                    prefix="bench_durability_")
                                tmpdirs.append(tmp)
                                manager = DurabilityManager(
                                    tmp.name,
                                    snapshot_every=args.snapshot_every)
                            if transport == "inproc":
                                pool = SessionPool(params, cfg,
                                                   capacity=args.capacity,
                                                   quant=quant, backend=backend,
                                                   inflight=inflight,
                                                   hops_per_step=hps,
                                                   step_fn=step,
                                                   durability=manager,
                                                   finite_guard=armed)
                                # warm up the compilation outside the timed points
                                w = pool.attach()
                                pool.feed(w, audio[0][: 2 * hps * cfg.hop])
                                pool.pump()
                                pool.detach(w)
                                runner = pool
                            else:
                                from repro.serve.gateway import GatewayThread
                                # one shard: same batched step as the in-process
                                # pool, so the delta IS the socket + gateway loop.
                                # guards=on arms the full containment plane here
                                # (finite guard + breaker + a generous watchdog
                                # that never fires on a healthy CPU run).
                                spool = ShardedSessionPool(
                                    params, cfg, args.capacity, shards=1,
                                    quant=quant, backend=backend,
                                    inflight=inflight, hops_per_step=hps,
                                    finite_guard=armed,
                                    breaker_threshold=3 if armed else None,
                                    watchdog_seconds=30.0 if armed else None)
                                h = spool.attach("warmup")
                                spool.feed(h, audio[0][: 2 * hps * cfg.hop])
                                spool.pump_all()
                                spool.detach(h)
                                runner = GatewayThread(spool, pump_interval=0.001)
                                gateways.append(runner)
                            combos.append((backend, hps, buffering, transport,
                                           durability, guard, manager, runner))
        # --repeats are INTERLEAVED across configurations (round-robin, min
        # wall-clock per point wins, as in timeit): a noisy scheduler phase
        # spanning one whole pass penalizes every config equally instead of
        # silently skewing the cross-config comparison ratios.
        best: dict = {}
        for _ in range(args.repeats):
            for (backend, hps, buffering, transport, durability, guard,
                 manager, runner) in combos:
                for n in sweep:
                    pre = manager.totals() if manager is not None else None
                    if transport == "inproc":
                        r = run_point(runner, n, audio)
                    else:
                        r = run_socket_point(runner, n, audio)
                    if manager is not None:
                        # raw I/O the manager performed during this point —
                        # delta, because totals() accumulate across repeats
                        post = manager.totals()
                        for field in ("journal_records", "journal_bytes",
                                      "snapshots", "snapshot_bytes"):
                            r[field] = post[field] - pre[field]
                    key = (backend, hps, buffering, transport, durability,
                           guard, n)
                    if key not in best or r["aggregate_rtf"] < best[key]["aggregate_rtf"]:
                        best[key] = r
        for gw in gateways:
            gw.stop()
        for (backend, hps, buffering, transport, durability, guard, _manager,
             _runner) in combos:
            for n in sweep:
                r = best[(backend, hps, buffering, transport, durability,
                          guard, n)]
                r.update(mode="sessions", backend=backend,
                         buffering=buffering, hops_per_step=hps,
                         transport=transport, durability=durability,
                         guards=guard)
                points.append(r)
                emit(
                    f"backend={backend} buffering={buffering} "
                    f"hops={hps} transport={transport} "
                    f"durability={durability} guards={guard} sessions={n}",
                    r["p50_ms"] * 1e3,
                    f"aggregate_rtf={r['aggregate_rtf']:.3f} "
                    f"rt_capacity={r['rt_capacity']:.1f} "
                    f"mean_session_rtf={r['mean_session_rtf']:.3f} "
                    f"p95_ms={r['p95_ms']:.2f} "
                    f"real_time={'yes' if r['aggregate_rtf'] < 1 else 'no'}",
                )
        for tmp in tmpdirs:
            tmp.cleanup()

    comparisons = {}
    if "xla" in backends and "pallas" in backends:
        comparisons["pallas_vs_xla"] = _ratio(points, "backend", "xla", "pallas")
    if "single" in bufferings and "double" in bufferings:
        comparisons["double_vs_single"] = _ratio(points, "buffering", "single", "double")
    if "inproc" in transports and "socket" in transports:
        # > 1.0 is the fabric's measured overhead (socket framing + gateway
        # pump loop) relative to direct pool calls on the same host
        comparisons["socket_vs_inproc"] = _ratio(points, "transport", "inproc", "socket")
    if "off" in durabilities and "on" in durabilities:
        # > 1.0 is the crash-recovery tax (write-ahead journal append per
        # feed + periodic ticket snapshot) relative to the same pool with
        # durability disabled
        comparisons["durability_vs_off"] = _ratio(points, "durability", "off", "on")
    if "off" in guard_modes and "on" in guard_modes:
        # > 1.0 is the containment tax (post-collect finite scan per hop,
        # plus breaker/watchdog bookkeeping on the socket transport); the
        # acceptance bar for a CPU smoke run is <= 1.05
        comparisons["guards_vs_off"] = _ratio(points, "guards", "off", "on")
    for k in hops_sweep:
        if k != 1 and 1 in hops_sweep and not args.adaptive:
            # < 1.0 means the fused path lowered aggregate RTF (a speedup of
            # 1/ratio); the acceptance bar for K=8 on a backlogged CPU smoke
            # run is <= 1/1.5
            comparisons[f"hops{k}_vs_hops1"] = _ratio(
                points, "hops_per_step", 1, k)
    if args.adaptive:
        # the self-tuning scheduler's scorecard: against the always-shallow
        # static pool (throughput headroom) and against the always-deep one
        # (p50 pump latency), on the SAME seeded bursty arrivals
        comparisons["adaptive_vs_hops1"] = _adaptive_ratio(points, 1)
        comparisons[f"adaptive_vs_hops{adaptive_kmax}"] = _adaptive_ratio(
            points, adaptive_kmax)
    result["comparisons"] = comparisons

    out_path = Path(args.json)
    out_path.write_text(json.dumps(result, indent=2) + "\n", encoding="utf-8")
    print(f"# wrote {out_path} ({len(points)} points)")

    if args.smoke and args.adaptive:
        # CI contract for the adaptive sweep: both scorecard ratios must be
        # populated (num_points and both metric means), else the sweep
        # silently lost a configuration
        for name in ("adaptive_vs_hops1", f"adaptive_vs_hops{adaptive_kmax}"):
            ratio = comparisons[name]
            if (not ratio["num_points"] or ratio["mean_rtf_ratio"] is None
                    or ratio["mean_p50_ratio"] is None):
                raise SystemExit(
                    f"smoke: {name} comparison is empty — adaptive points "
                    "found no matching static points"
                )
            print(f"# {name}: rtf_ratio={ratio['mean_rtf_ratio']:.3f} "
                  f"p50_ratio={ratio['mean_p50_ratio']:.3f} "
                  f"({ratio['num_points']} matched points)")
    if args.smoke and not args.adaptive:
        # CI contract: a smoke sweep must actually produce the comparison
        # fields it claims (an empty ratio means the sweep silently skipped
        # a configuration)
        for k in hops_sweep:
            if k == 1 or 1 not in hops_sweep:
                continue
            ratio = comparisons[f"hops{k}_vs_hops1"]
            if not ratio["num_points"] or ratio["mean_rtf_ratio"] is None:
                raise SystemExit(
                    f"smoke: hops{k}_vs_hops1 comparison is empty — the "
                    f"K={k} sweep produced no points matching the K=1 sweep"
                )
            print(f"# hops{k}_vs_hops1 mean RTF ratio: "
                  f"{ratio['mean_rtf_ratio']:.3f} "
                  f"({1.0 / ratio['mean_rtf_ratio']:.2f}x speedup)")
    if args.smoke and "on" in durabilities:
        # CI contract for the durability sweep: every durable point must
        # carry the manager's I/O accounting, and journaling must actually
        # have happened (a zero journal_bytes point means feeds bypassed the
        # write-ahead log and the overhead being measured is fiction)
        durable_points = [p for p in points
                          if p.get("mode") == "sessions"
                          and p.get("durability") == "on"]
        if not durable_points:
            raise SystemExit("smoke: --durability on produced no points")
        for p in durable_points:
            for field in ("journal_records", "journal_bytes", "snapshots",
                          "snapshot_bytes"):
                if field not in p:
                    raise SystemExit(
                        f"smoke: durable point missing {field!r}")
            if p["journal_bytes"] <= 0 or p["journal_records"] <= 0:
                raise SystemExit(
                    "smoke: durable point recorded no journal writes")
        if "off" in durabilities:
            ratio = comparisons["durability_vs_off"]
            if not ratio["num_points"] or ratio["mean_rtf_ratio"] is None:
                raise SystemExit(
                    "smoke: durability_vs_off comparison is empty — the "
                    "durable sweep produced no points matching the "
                    "non-durable sweep"
                )
            print(f"# durability_vs_off mean RTF ratio: "
                  f"{ratio['mean_rtf_ratio']:.3f} "
                  f"(journal_bytes/point max "
                  f"{max(p['journal_bytes'] for p in durable_points)})")
    if args.smoke and "on" in guard_modes:
        # CI contract for the guards sweep: guarded points must exist, and
        # when both modes ran, the containment tax must stay within the
        # <= 5% acceptance bar (best-of-N repeats keep this comparison out
        # of scheduler-noise territory)
        guarded_points = [p for p in points
                          if p.get("mode") == "sessions"
                          and p.get("guards") == "on"]
        if not guarded_points:
            raise SystemExit("smoke: --guards on produced no points")
        if "off" in guard_modes:
            ratio = comparisons["guards_vs_off"]
            if not ratio["num_points"] or ratio["mean_rtf_ratio"] is None:
                raise SystemExit(
                    "smoke: guards_vs_off comparison is empty — the guarded "
                    "sweep produced no points matching the unguarded sweep"
                )
            print(f"# guards_vs_off mean RTF ratio: "
                  f"{ratio['mean_rtf_ratio']:.3f} "
                  f"({ratio['num_points']} matched points)")
            # the <= 5% bar is the POOL's guard tax: enforce it on the
            # inproc subset, where the only delta is the finite scan (the
            # socket points fold in gateway pump-loop jitter that has
            # nothing to do with the guard itself)
            if "inproc" in transports:
                inproc = _ratio([p for p in points
                                 if p.get("transport") == "inproc"],
                                "guards", "off", "on")
                if (inproc["mean_rtf_ratio"] is not None
                        and inproc["mean_rtf_ratio"] > 1.05):
                    raise SystemExit(
                        f"smoke: guards overhead "
                        f"{inproc['mean_rtf_ratio']:.3f}x on the inproc "
                        "sweep exceeds the 1.05x acceptance bar"
                    )


if __name__ == "__main__":
    main()

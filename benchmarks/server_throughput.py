"""Multi-session server throughput: sessions x RTF curve, single or sharded.

Default mode sweeps the number of concurrent streams served by ONE
fixed-capacity ``SessionPool`` (one compiled batched hop step, no
recompilation across sweep points — the server's core scaling property) and
reports, per point:

- aggregate RTF: total compute seconds per total audio seconds (< 1 means the
  whole batch is served in real time),
- per-session RTF (mean),
- pool step latency p50/p95 in ms against the 16 ms hop budget.

``--shards N`` instead sweeps SHARD COUNT at full per-shard load through
``ShardedSessionPool`` (one pool per device, overlapped ``pump_all``) and
reports aggregate RTF plus ``rt_capacity = 1 / aggregate_rtf`` — the number
of real-time streams this host could sustain at that shard count. If
capacity scales linearly with devices, rt_capacity grows ~linearly in the
shard sweep (faked CPU devices share one core: expect a flat curve there).
On a CPU-only host, fake devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python benchmarks/server_throughput.py --shards 4

CSV on stdout via benchmarks.common.emit. Designed to finish well inside
2 minutes on a laptop CPU (reduced trunk, ~1 s of audio per session).

Flags (see also --help): --capacity N (slots: per pool, or per shard when
--shards > 0), --seconds S (audio per session), --quant (FP10 grid),
--shards N (sweep 1..N shards; 0 = single-pool sessions sweep).

Run:  PYTHONPATH=src python benchmarks/server_throughput.py [--capacity N] \\
          [--seconds S] [--quant] [--shards N]
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from common import emit  # noqa: E402

from repro.audio.synthetic import batch_for_step  # noqa: E402
from repro.core.quant import FP10  # noqa: E402
from repro.launch.serve import reduced_cfg  # noqa: E402
from repro.models import tftnn as tft  # noqa: E402
from repro.serve import SessionPool, ShardedSessionPool  # noqa: E402


def bench_cfg() -> tft.TFTConfig:
    """Paper front end (512/128 @ 8 kHz), reduced trunk for CPU wall-clock —
    the same profile the launcher's --reduced flag uses."""
    return reduced_cfg(tft.tftnn_config())


def run_point(pool: SessionPool, n_sessions: int, audio: np.ndarray) -> dict:
    sessions = [pool.attach() for _ in range(n_sessions)]
    pool.step_seconds.clear()
    for i, s in enumerate(sessions):
        pool.feed(s, audio[i % audio.shape[0]])
    pool.pump()
    hop, sr = pool.cfg.hop, pool.sample_rate
    proc = float(sum(pool.step_seconds))
    audio_sec = sum(s.stats.hops for s in sessions) * hop / sr
    rtfs = [s.stats.rtf(sr, hop) for s in sessions]
    pct = pool.latency_percentiles()
    for s in sessions:
        pool.detach(s)
    return {
        "aggregate_rtf": proc / audio_sec,
        "mean_session_rtf": float(np.mean(rtfs)),
        "p50_ms": pct[50],
        "p95_ms": pct[95],
    }


def run_sharded_point(params, cfg, n_shards: int, per_shard: int,
                      audio: np.ndarray, quant, step_cache: dict) -> dict:
    """One shard-sweep point: fill n_shards x per_shard sessions, pump_all.

    ``step_cache`` is shared across sweep points so each device compiles the
    hop step once for the whole sweep (cfg/capacity/quant are constant)."""
    pool = ShardedSessionPool(params, cfg, per_shard, shards=n_shards,
                              quant=quant, step_cache=step_cache)
    n_sessions = n_shards * per_shard
    handles = [pool.attach(f"bench-{i}", rebalance_on_full=True)
               for i in range(n_sessions)]
    # warm up each shard's one compilation outside the timed window
    for i, h in enumerate(handles):
        pool.feed(h, audio[i % audio.shape[0]][: 2 * cfg.hop])
    pool.pump_all()
    warm_hops = sum(h.stats.hops for h in handles)  # exclude from timed audio
    for i, h in enumerate(handles):
        pool.feed(h, audio[i % audio.shape[0]])
    t0 = time.perf_counter()
    pool.pump_all()
    wall = time.perf_counter() - t0
    timed_hops = sum(h.stats.hops for h in handles) - warm_hops
    audio_sec = timed_hops * cfg.hop / pool.sample_rate
    rtf = wall / audio_sec
    for h in handles:
        pool.detach(h)
    return {
        "sessions": n_sessions,
        "aggregate_rtf": rtf,
        # sustainable real-time streams: total audio seconds / wall second.
        # rtf's denominator already sums audio over every session, so this is
        # 1/rtf — NOT sessions/rtf, which would double-count session count.
        "rt_capacity": 1.0 / rtf if rtf > 0 else float("inf"),
        "wall_s": wall,
    }


def _shard_sweep(n_max: int) -> list:
    s, out = 1, []
    while s < n_max:
        out.append(s)
        s *= 2
    out.append(n_max)
    return sorted(set(out))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Multi-session server throughput: sessions x RTF "
        "(single pool) or shard-count sweep (--shards, one pool per device)."
    )
    ap.add_argument("--capacity", type=int, default=16,
                    help="slots compiled into each pool (per shard when --shards > 0)")
    ap.add_argument("--seconds", type=float, default=1.0,
                    help="seconds of audio fed to each session")
    ap.add_argument("--quant", action="store_true",
                    help="serve on the paper's FP10 deployment grid")
    ap.add_argument("--shards", type=int, default=0,
                    help="sweep ShardedSessionPool from 1 up to N shards at full "
                    "per-shard load (0 = single-pool sessions sweep); fake CPU "
                    "devices with XLA_FLAGS=--xla_force_host_platform_device_count=N")
    args = ap.parse_args()

    cfg = bench_cfg()
    params = tft.init_tft(jax.random.PRNGKey(0), cfg)
    quant = FP10 if args.quant else None

    sample_rate = 8000
    # at least one whole hop, else nothing is ever enhanced (div-by-zero)
    samples = max(cfg.hop, int(args.seconds * sample_rate) // cfg.hop * cfg.hop)
    noisy, _ = batch_for_step(1, 0, batch=4, num_samples=samples)
    audio = np.asarray(noisy, np.float32)
    budget_ms = cfg.hop / sample_rate * 1e3

    if args.shards > 0:
        n_dev = len(jax.local_devices())
        print(f"# shard sweep up to {args.shards}, capacity/shard={args.capacity}, "
              f"audio/session={args.seconds}s, {n_dev} local device(s), "
              f"quant={'fp10' if args.quant else 'fp32'}")
        print("name,us_per_call,derived")
        step_cache = {}  # one compilation per device across the whole sweep
        for s in _shard_sweep(args.shards):
            r = run_sharded_point(params, cfg, s, args.capacity, audio, quant,
                                  step_cache)
            emit(
                f"shards={s}",
                r["wall_s"] * 1e6,
                f"sessions={r['sessions']} aggregate_rtf={r['aggregate_rtf']:.3f} "
                f"rt_capacity={r['rt_capacity']:.1f} "
                f"real_time={'yes' if r['aggregate_rtf'] < 1 else 'no'}",
            )
        return

    pool = SessionPool(params, cfg, capacity=args.capacity, quant=quant)

    # warm up the single compilation the whole sweep reuses
    w = pool.attach()
    pool.feed(w, audio[0][: 4 * cfg.hop])
    pool.pump()
    pool.detach(w)

    print(f"# capacity={args.capacity} audio/session={args.seconds}s "
          f"hop_budget={budget_ms:.1f}ms quant={'fp10' if args.quant else 'fp32'}")
    print("name,us_per_call,derived")
    sweep = [n for n in (1, 2, 4, 8, 16) if n <= args.capacity]
    for n in sweep:
        r = run_point(pool, n, audio)
        emit(
            f"sessions={n}",
            r["p50_ms"] * 1e3,
            f"aggregate_rtf={r['aggregate_rtf']:.3f} "
            f"mean_session_rtf={r['mean_session_rtf']:.3f} "
            f"p95_ms={r['p95_ms']:.2f} real_time={'yes' if r['aggregate_rtf'] < 1 else 'no'}",
        )


if __name__ == "__main__":
    main()

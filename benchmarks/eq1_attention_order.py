"""Eq. 1 / Fig. 11: softmax-free attention optimal-order speedup.

Verifies the h/w MAC-count ratio analytically (exact) and measures the wall
speedup of Q(K^T V) vs (Q K^T)V on this host at the paper's dims (h=128, w=8)
and at LM-scale dims.
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core.softmax_free_attention import (
    attention_mac_counts,
    softmax_free_attention,
    softmax_free_attention_quadratic,
)


def run() -> None:
    key = jax.random.PRNGKey(0)
    for (L, D, tag) in ((128, 8, "paper_dims"), (4096, 128, "lm_dims")):
        orig, new = attention_mac_counts(L, D)
        q, k, v = (jax.random.normal(kk, (8, 4, L, D)) for kk in jax.random.split(key, 3))
        f_new = jax.jit(softmax_free_attention)
        f_old = jax.jit(lambda a, b, c: softmax_free_attention_quadratic(a, b, c))
        t_new = time_fn(f_new, q, k, v)
        t_old = time_fn(f_old, q, k, v)
        emit(f"eq1/{tag}", t_new,
             f"mac_ratio={orig / new:.1f} (paper 16x at h=128,w=8) measured_speedup={t_old / t_new:.2f}x")


if __name__ == "__main__":
    run()

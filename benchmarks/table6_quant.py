"""Table VI: post-training quantization ladder FP{32,16,10,9,8} / FxP{16,10,9,8}.

Trains one tiny TFTNN, then post-quantizes weights+activations per scheme and
scores enhancement quality — reproducing the paper's finding that FP10
(1-5-4) is nearly lossless while FxP<=10 collapses (dynamic range 1e-8..30).
Activation quantization is applied to the model input/output paths; weight
quantization to every parameter leaf.
"""

from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit
from repro.audio.metrics import all_metrics
from repro.audio.synthetic import batch_for_step
from repro.core import quant
from repro.core.quant import quantize_tree
from repro.train.train_loop import make_se_eval_step
from benchmarks.table2_domain import BATCH, SAMPLES, _train

STEPS = 60

LADDER = (
    ("fp32", quant.NONE),
    ("fp16", quant.FP16),
    ("fp10", quant.FP10),
    ("fp9", quant.FP9),
    ("fp8", quant.FP8),
    ("fxp16", quant.FXP16),
    ("fxp10", quant.FXP10),
    ("fxp9", quant.FXP9),
    ("fxp8", quant.FXP8),
)


def run(steps: int = STEPS) -> None:
    from repro.models.tftnn import tftnn_config

    cfg = dataclasses.replace(
        tftnn_config(), freq_bins=64, channels=16, att_dim=8, num_heads=1, gru_hidden=16,
        dilation_rates=(1, 2, 4),
    )
    state = _train(cfg, "t+f", steps)
    ev = make_se_eval_step(cfg)
    noisy, clean = batch_for_step(123, 0, batch=8, num_samples=SAMPLES)
    for tag, spec in LADDER:
        params = quantize_tree(state["params"], spec)
        est = ev(params, quant.quantize(noisy, spec))
        est = quant.quantize(est, spec)
        s = {k: float(v) for k, v in all_metrics(est, clean).items()}
        emit(f"table6/{tag}", 0.0,
             f"si_snr={s['si_snr']:.2f} stoi_proxy={s['stoi_proxy']:.3f} snr={s['snr']:.2f}")


if __name__ == "__main__":
    run()

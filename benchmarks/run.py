"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Trained-ablation tables
(II/III/IV/VI) run short CPU trainings of reduced models — pass --quick to
shrink them further, --full for the paper-faithful step counts.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer training steps")
    ap.add_argument("--only", default=None, help="comma-separated table names")
    args, _ = ap.parse_known_args()

    from benchmarks import (
        eq1_attention_order,
        fig9_ln_bn_cycles,
        realtime_budget,
        roofline_report,
        table1_models,
        table2_domain,
        table3_blocks,
        table4_bn_ln,
        table6_quant,
        table7_compression,
    )

    steps2 = 12 if args.quick else 60
    steps3 = 8 if args.quick else 40
    suites = [
        ("table1", table1_models.run),
        ("table2", lambda: table2_domain.run(steps2)),
        ("table3", lambda: table3_blocks.run(steps3)),
        ("table4", lambda: table4_bn_ln.run(steps3)),
        ("table6", lambda: table6_quant.run(steps2)),
        ("table7", table7_compression.run),
        ("eq1", eq1_attention_order.run),
        ("fig9", fig9_ln_bn_cycles.run),
        ("realtime", realtime_budget.run),
        ("roofline", roofline_report.run),
    ]
    only = set(args.only.split(",")) if args.only else None
    failed = []
    for name, fn in suites:
        if only and name not in only:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001 — report all suites
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Roofline report: aggregates results/dryrun/*.json into the §Roofline table."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run(results_dir: str = RESULTS) -> None:
    files = sorted(glob.glob(os.path.join(results_dir, "*.json")))
    if not files:
        emit("roofline/none", 0.0, "no dry-run results yet (run python -m repro.launch.dryrun)")
        return
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        name = os.path.basename(f).replace(".json", "")
        if r.get("skipped"):
            emit(f"roofline/{name}", 0.0, "SKIP " + r["reason"][:60])
            continue
        emit(
            f"roofline/{name}",
            r["bound_time"] * 1e6,
            f"dom={r['dominant']} tc={r['t_compute']*1e3:.2f}ms tm={r['t_memory']*1e3:.2f}ms "
            f"tcoll={r['t_collective']*1e3:.2f}ms frac={r['roofline_fraction']:.3f} "
            f"useful={r['useful_flop_fraction']:.2f} mem/dev={r['bytes_per_device']/1e9:.2f}GB",
        )


if __name__ == "__main__":
    run()

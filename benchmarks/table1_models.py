"""Table I: model size / computation comparison (TSTNN vs TFTNN).

Reproduces the paper's headline numbers: parameters and GMAC/s (1 s of 8 kHz
audio) for the baseline and the compressed model, plus forward wall time on
this host for reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.models.tftnn import (
    apply_tft, gmacs_per_second, init_tft, param_count, tftnn_config, tstnn_config,
)


def run() -> None:
    key = jax.random.PRNGKey(0)
    spec = jax.random.normal(key, (1, 257, 63, 2))  # 1 s at 8 kHz
    for cfg, paper_params, paper_gmac in (
        (tstnn_config(), 922.9e3, 9.87),
        (tftnn_config(), 55.9e3, 0.496),
    ):
        params = init_tft(key, cfg)
        n = param_count(params)
        g = gmacs_per_second(cfg)
        fwd = jax.jit(lambda p, x: apply_tft(p, x, cfg)[0])
        us = time_fn(fwd, params, spec)
        emit(
            f"table1/{cfg.name}",
            us,
            f"params={n} (paper {paper_params:.0f}) gmacs={g:.3f} (paper {paper_gmac})",
        )
    tst, tft = param_count(init_tft(key, tstnn_config())), param_count(init_tft(key, tftnn_config()))
    emit("table1/size_reduction", 0.0,
         f"reduction={1 - tft / tst:.3f} (paper 0.939)")
    emit("table1/gmac_reduction", 0.0,
         f"reduction={1 - gmacs_per_second(tftnn_config()) / gmacs_per_second(tstnn_config()):.3f} (paper 0.949)")


if __name__ == "__main__":
    run()

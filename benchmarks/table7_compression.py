"""Table VII: the four main compression methods' size/GMAC ladder."""

from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core.pruning import apply_ladder
from repro.models.tftnn import gmacs_per_second, init_tft, param_count, tstnn_config

PAPER = {
    "baseline": (922.87, 9.87),
    "R": (449.95, 3.83),
    "R+S": (348.58, 3.01),
    "R+S+halfch": (89.30, 0.782),
    "R+S+halfch+halfTr": (55.92, 0.496),
}

LADDER = [
    ("baseline", []),
    ("R", ["R"]),
    ("R+S", ["R", "S"]),
    ("R+S+halfch", ["R", "S", "half_ch"]),
    ("R+S+halfch+halfTr", ["R", "S", "half_ch", "half_blocks", "K", "G", "P"]),
]


def run() -> None:
    key = jax.random.PRNGKey(0)
    base = tstnn_config()
    for name, steps in LADDER:
        cfg = apply_ladder(base, steps)
        n = param_count(init_tft(key, cfg)) / 1e3
        g = gmacs_per_second(cfg)
        pn, pg = PAPER[name]
        emit(f"table7/{name}", 0.0,
             f"size_k={n:.2f} (paper {pn}) gmac={g:.3f} (paper {pg})")


if __name__ == "__main__":
    run()

"""Pruning Pareto: granularity x keep-ratio vs serving RTF and SI-SNR.

The paper ships a 93.9%-pruned model because pruned MACs are gated off in
hardware; the repo's analogue is the masked-MAC skip plan (strip/tile/
column, ``kernels.masked_mac``). This benchmark measures what each pruning
granularity actually buys AT SERVING TIME, on real (fine-tuned) weights:

- every (granularity, keep) point fine-tunes the SAME dense-trained
  checkpoint with its masks frozen (``train.finetune_prune`` — projected
  descent, exact realized sparsity), so quality differences are the
  pruning's, not initialization luck;
- RTF is measured through the serving stack — a ``SessionPool`` per
  configuration, fused multi-hop dispatch, interleaved best-of-N repeats
  (round-robin across configurations, min wall per point, exactly like
  ``server_throughput.py``) so scheduler noise hits every point equally;
- the DENSE baseline serves ``prune_keep=1.0`` — the same deploy-compiled
  folded graph as the sparse points, just without masks — so the
  ``rtf_vs_dense`` ratios compare skip plans, never graph flavors;
- quality is batch SI-SNR of the pool's own served output against the
  clean fixture signal (``benchmarks.eval_sisnr`` helpers), with the
  unenhanced noisy baseline reported for scale.

The benchmark config is deliberately matmul-heavy (wide channels, 1x1
convs, thin attention/GRU): the four masked weights then dominate per-hop
compute, which is the regime where granular skipping is measurable on a
CPU host at all. On one core, column skipping (unit masks) wins outright;
strip/tile plans mostly document their accounting — the tile path's MXU
payoff needs real accelerator tiles.

Output: CSV rows + ``BENCH_prune_pareto.json`` with every point (measured
RTF, SI-SNR, exact realized sparsity, kernel skip rate), the RTF-vs-SI-SNR
``frontier`` (non-dominated set), per-granularity ``granularity_vs_dense``
RTF ratios, and a ``claims`` block naming the best sparse point that beats
dense RTF within 1 dB SI-SNR. ``--smoke`` shrinks everything and fails if
any of those fields comes out empty — the CI contract.

Run:  PYTHONPATH=src python benchmarks/prune_pareto.py [--keeps 0.25,0.5,0.75]
          [--granularities weight,block,unit] [--train-steps N]
          [--finetune-steps N] [--sessions N] [--seconds S] [--repeats N]
          [--hops-per-step K] [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from common import emit  # noqa: E402
from eval_sisnr import pair_si_snr  # noqa: E402

from repro.audio.synthetic import batch_for_step  # noqa: E402
from repro.models import tftnn as tft  # noqa: E402
from repro.serve import SessionPool  # noqa: E402
from repro.train.finetune_prune import (  # noqa: E402
    finetune_pruned,
    realized_keep,
    train_dense,
)

SAMPLE_RATE = 8000


def bench_cfg() -> tft.TFTConfig:
    """Matmul-heavy serving profile: wide channels, 1x1 convs, thin trunk.

    The four masked-MAC weights (att_in/att_out/mask_conv1/mask_conv2) are
    all C-wide matmuls, so C=256 with kf=1 convs puts most per-hop FLOPs
    into exactly the weights pruning can skip — the regime where the
    granularity comparison measures skip plans instead of fixed overhead.
    """
    return dataclasses.replace(
        tft.tftnn_config(), n_fft=256, hop=64, freq_bins=128,
        channels=256, att_dim=8, num_heads=1, gru_hidden=8,
        num_transformer_blocks=1, dilation_rates=(1,), conv_kernel_f=1,
        downsample=2,
    )


def smoke_cfg() -> tft.TFTConfig:
    """CI-sized profile: same shape family, seconds-not-minutes to train."""
    return dataclasses.replace(
        bench_cfg(), n_fft=64, hop=16, freq_bins=32, channels=32,
    )


def run_point(pool: SessionPool, audio: np.ndarray) -> dict:
    """Feed one utterance per session, pump, return wall/RTF + outputs."""
    sessions = [pool.attach() for _ in range(audio.shape[0])]
    for i, s in enumerate(sessions):
        pool.feed(s, audio[i])
    t0 = time.perf_counter()
    pool.pump()
    wall = time.perf_counter() - t0
    hop = pool.cfg.hop
    audio_sec = sum(s.stats.hops for s in sessions) * hop / pool.sample_rate
    outs = [pool.read(s) for s in sessions]
    for s in sessions:
        pool.detach(s)
    rtf = wall / audio_sec
    return {"wall_s": wall, "aggregate_rtf": rtf, "outs": outs}


def _csv_floats(raw: str, what: str) -> list:
    try:
        vals = [float(v) for v in raw.split(",") if v.strip()]
    except ValueError:
        raise SystemExit(f"{what} must be a comma list of floats, got {raw!r}")
    if not vals or any(not 0.0 < v < 1.0 for v in vals):
        raise SystemExit(f"{what} needs keep fractions in (0, 1), got {raw!r}")
    return vals


def _frontier(points: list) -> list:
    """Non-dominated subset: lower RTF and higher SI-SNR both win."""
    front = []
    for p in points:
        dominated = any(
            q is not p
            and q["aggregate_rtf"] <= p["aggregate_rtf"]
            and q["si_snr_db"] >= p["si_snr_db"]
            and (q["aggregate_rtf"] < p["aggregate_rtf"]
                 or q["si_snr_db"] > p["si_snr_db"])
            for q in points
        )
        if not dominated:
            front.append({k: p[k] for k in
                          ("label", "granularity", "keep", "aggregate_rtf",
                           "si_snr_db")})
    return sorted(front, key=lambda p: p["aggregate_rtf"])


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Pruning Pareto through the serving stack: granularity "
        "x keep sweep, fine-tuned checkpoints, RTF-vs-SI-SNR frontier in "
        "BENCH_prune_pareto.json."
    )
    ap.add_argument("--keeps", default="0.25,0.5,0.75",
                    help="comma list of keep fractions in (0, 1) to sweep")
    ap.add_argument("--granularities", default="weight,block,unit",
                    help="comma list of mask granularities to sweep")
    ap.add_argument("--prune-block", default="8,8",
                    help="'bk,bn' tile shape for block masks / skip units")
    ap.add_argument("--train-steps", type=int, default=48,
                    help="dense pre-training steps (shared ancestor of "
                    "every sweep point)")
    ap.add_argument("--finetune-steps", type=int, default=16,
                    help="mask-frozen fine-tuning steps per sweep point")
    ap.add_argument("--train-samples", type=int, default=2048,
                    help="samples per training utterance")
    ap.add_argument("--sessions", type=int, default=8,
                    help="concurrent streams per RTF point (= fixture "
                    "utterances scored for SI-SNR)")
    ap.add_argument("--seconds", type=float, default=1.0,
                    help="seconds of audio per session")
    ap.add_argument("--hops-per-step", type=int, default=4,
                    help="fused dispatch depth of every pool")
    ap.add_argument("--repeats", type=int, default=5,
                    help="interleaved best-of-N repeats per RTF point")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: tiny config, 2 sessions, minimal "
                    "training; fails if the JSON lacks frontier/ratio/"
                    "skip-rate fields")
    ap.add_argument("--json", default="BENCH_prune_pareto.json",
                    help="where to write the machine-readable results")
    args = ap.parse_args()

    keeps = _csv_floats(args.keeps, "--keeps")
    grans = [g.strip() for g in args.granularities.split(",") if g.strip()]
    for g in grans:
        if g not in ("weight", "block", "unit"):
            raise SystemExit(f"unknown granularity {g!r}")
    try:
        bk, bn = (int(v) for v in args.prune_block.split(","))
    except ValueError:
        raise SystemExit(f"--prune-block must be 'bk,bn', got {args.prune_block!r}")
    if args.smoke:
        cfg = smoke_cfg()
        args.train_steps = min(args.train_steps, 2)
        args.finetune_steps = min(args.finetune_steps, 1)
        args.train_samples = min(args.train_samples, 512)
        args.sessions = min(args.sessions, 2)
        args.seconds = min(args.seconds, 0.25)
        args.repeats = min(args.repeats, 2)
        keeps = keeps[:1]
        bk, bn = min(bk, 4), min(bn, 4)
    else:
        cfg = bench_cfg()

    print(f"# training dense ancestor: {args.train_steps} steps "
          f"(C={cfg.channels}, F={cfg.freq_bins})")
    t0 = time.perf_counter()
    dense_params, dense_losses = train_dense(
        cfg, steps=args.train_steps, batch=2,
        num_samples=args.train_samples, seed=0,
    )
    print(f"# dense loss {dense_losses[0]:.4f} -> {dense_losses[-1]:.4f} "
          f"({time.perf_counter() - t0:.1f}s)")

    configs = [{"label": "dense", "granularity": None, "keep": 1.0,
                "params": dense_params, "finetune_losses": None}]
    for g in grans:
        for k in keeps:
            t0 = time.perf_counter()
            p, _, fl = finetune_pruned(
                dense_params, cfg, keep=k, granularity=g, block=(bk, bn),
                steps=args.finetune_steps, batch=2,
                num_samples=args.train_samples, seed=100,
            )
            print(f"# finetuned {g}/keep={k}: loss {fl[0]:.4f} -> "
                  f"{fl[-1]:.4f} ({time.perf_counter() - t0:.1f}s)")
            configs.append({"label": f"{g}-{k}", "granularity": g, "keep": k,
                            "params": p, "finetune_losses": fl})

    samples = max(cfg.hop, int(args.seconds * SAMPLE_RATE) // cfg.hop * cfg.hop)
    noisy, clean = batch_for_step(1, 0, batch=args.sessions, num_samples=samples)
    noisy = np.asarray(noisy, np.float32)
    clean = np.asarray(clean, np.float32)
    base_si = float(np.mean([
        pair_si_snr(noisy[i], clean[i])[0] for i in range(args.sessions)
    ]))

    pools = []
    for c in configs:
        t0 = time.perf_counter()
        pool = SessionPool(
            c["params"], cfg, capacity=args.sessions, backend="xla",
            prune_keep=c["keep"],  # 1.0 = dense through the same deploy graph
            prune_granularity=c["granularity"], prune_block=(bk, bn),
            hops_per_step=args.hops_per_step,
        )
        w = pool.attach()
        pool.feed(w, noisy[0][: 2 * args.hops_per_step * cfg.hop])
        pool.pump()
        pool.detach(w)
        pools.append(pool)
        print(f"# compiled {c['label']} ({time.perf_counter() - t0:.1f}s)")

    # interleaved best-of-N: round-robin over configs each repeat, min wall
    # per point wins, so a noisy scheduler phase cannot skew one point
    best = [None] * len(configs)
    outs = [None] * len(configs)
    for _ in range(args.repeats):
        for i, pool in enumerate(pools):
            r = run_point(pool, noisy)
            if outs[i] is None:
                outs[i] = r["outs"]  # deterministic across repeats
            if best[i] is None or r["aggregate_rtf"] < best[i]["aggregate_rtf"]:
                best[i] = {k: r[k] for k in ("wall_s", "aggregate_rtf")}

    points = []
    print("name,us_per_call,derived")
    for i, (c, pool) in enumerate(zip(configs, pools)):
        est = outs[i]
        n = min(o.size for o in est)
        si = float(np.mean([
            pair_si_snr(est[j][:n], clean[j][:n])[0]
            for j in range(args.sessions)
        ]))
        prune = pool.shard_stats().get("prune")
        rk = realized_keep(c["params"])["total"] if c["keep"] < 1.0 else 1.0
        point = {
            "label": c["label"],
            "granularity": c["granularity"],
            "keep": c["keep"],
            "aggregate_rtf": best[i]["aggregate_rtf"],
            "wall_s": best[i]["wall_s"],
            "si_snr_db": si,
            "realized_keep": prune["realized_keep"] if prune else rk,
            "realized_sparsity": prune["realized_sparsity"] if prune else 0.0,
            "skip_rate": prune["skip_rate"] if prune else 0.0,
            "skip_granularity": prune["skip_granularity"] if prune else None,
            "skip_counters": prune["skip_counters"] if prune else None,
            "checkpoint_realized_keep": rk,
            "finetune_loss_first": c["finetune_losses"][0] if c["finetune_losses"] else None,
            "finetune_loss_last": c["finetune_losses"][-1] if c["finetune_losses"] else None,
        }
        points.append(point)
    dense_pt = points[0]
    for p in points:
        p["rtf_vs_dense"] = p["aggregate_rtf"] / dense_pt["aggregate_rtf"]
        p["si_snr_vs_dense_db"] = p["si_snr_db"] - dense_pt["si_snr_db"]
        emit(
            f"config={p['label']}",
            p["wall_s"] * 1e6,
            f"rtf={p['aggregate_rtf']:.3f} rtf_vs_dense={p['rtf_vs_dense']:.3f} "
            f"si_snr={p['si_snr_db']:.2f}dB d_si={p['si_snr_vs_dense_db']:+.2f}dB "
            f"sparsity={p['realized_sparsity']:.3f} skip_rate={p['skip_rate']:.3f}",
        )

    gvd = {}
    for g in grans:
        ratios = {str(p["keep"]): p["rtf_vs_dense"]
                  for p in points if p["granularity"] == g}
        gvd[g] = {"rtf_vs_dense": ratios,
                  "best_rtf_vs_dense": min(ratios.values())}
    sparse = [p for p in points if p["keep"] < 1.0]
    winners = [p for p in sparse
               if p["aggregate_rtf"] < dense_pt["aggregate_rtf"]
               and p["si_snr_db"] >= dense_pt["si_snr_db"] - 1.0]
    witness = min(winners, key=lambda p: p["aggregate_rtf"]) if winners else None
    result = {
        "benchmark": "prune_pareto",
        "config": {
            "model": {"n_fft": cfg.n_fft, "hop": cfg.hop,
                      "freq_bins": cfg.freq_bins, "channels": cfg.channels,
                      "att_dim": cfg.att_dim,
                      "blocks": cfg.num_transformer_blocks},
            "keeps": keeps, "granularities": grans,
            "prune_block": [bk, bn],
            "train_steps": args.train_steps,
            "finetune_steps": args.finetune_steps,
            "sessions": args.sessions, "seconds": args.seconds,
            "hops_per_step": args.hops_per_step, "repeats": args.repeats,
            "backend": "xla", "smoke": args.smoke,
            "jax_backend": jax.default_backend(),
            "noisy_baseline_si_snr_db": base_si,
            "dense_train_loss_first": dense_losses[0],
            "dense_train_loss_last": dense_losses[-1],
        },
        "points": points,
        "frontier": _frontier(points),
        "granularity_vs_dense": gvd,
        "claims": {
            "sparse_beats_dense_within_1db": witness is not None,
            "witness": ({k: witness[k] for k in
                         ("label", "aggregate_rtf", "rtf_vs_dense",
                          "si_snr_db", "si_snr_vs_dense_db",
                          "realized_sparsity", "skip_rate")}
                        if witness else None),
        },
    }
    out_path = Path(args.json)
    out_path.write_text(json.dumps(result, indent=2) + "\n", encoding="utf-8")
    print(f"# wrote {out_path} ({len(points)} points, "
          f"{len(result['frontier'])} on the frontier)")
    if witness:
        print(f"# witness: {witness['label']} rtf_vs_dense="
              f"{witness['rtf_vs_dense']:.3f} "
              f"d_si={witness['si_snr_vs_dense_db']:+.2f}dB")

    if args.smoke:
        # CI contract: the artifact must carry the fields the Pareto claims
        missing = []
        if not result["frontier"]:
            missing.append("frontier")
        for g in grans:
            if not gvd.get(g, {}).get("rtf_vs_dense"):
                missing.append(f"granularity_vs_dense[{g}]")
        for p in points:
            for field in ("aggregate_rtf", "si_snr_db", "realized_sparsity",
                          "skip_rate", "rtf_vs_dense"):
                if p.get(field) is None:
                    missing.append(f"points[{p['label']}].{field}")
        if "claims" not in result or "sparse_beats_dense_within_1db" not in result["claims"]:
            missing.append("claims.sparse_beats_dense_within_1db")
        if missing:
            raise SystemExit(f"smoke: JSON missing fields: {missing}")
        print("# smoke: all frontier/ratio/skip-rate fields present")


if __name__ == "__main__":
    main()

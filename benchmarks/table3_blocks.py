"""Table III: transformer-block-count ablation (1/2/3/4 blocks)."""

from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit
from repro.models.tftnn import init_tft, param_count, tftnn_config
from benchmarks.table2_domain import _score, _train

STEPS = 40


def run(steps: int = STEPS) -> None:
    base = dataclasses.replace(
        tftnn_config(), freq_bins=64, channels=16, att_dim=8, num_heads=1, gru_hidden=16,
        dilation_rates=(1, 2),
    )
    for blocks in (1, 2, 3, 4):
        cfg = dataclasses.replace(base, num_transformer_blocks=blocks)
        state = _train(cfg, "t+f", steps, seed=blocks)
        s = _score(cfg, state)
        n = param_count(init_tft(jax.random.PRNGKey(0), cfg))
        emit(f"table3/blocks={blocks}", 0.0,
             f"params={n} si_snr={s['si_snr']:.2f} stoi_proxy={s['stoi_proxy']:.3f}")


if __name__ == "__main__":
    run()

"""Fig. 9: LN vs BN normalization schedule (ASIC cycle model + host timing).

The paper claims the LN->BN swap cuts normalization cycles by ~2/3 (LN needs
3 serial passes: mean, variance, normalize; BN is one constant-affine pass)
and, after folding, BN costs nothing. We reproduce the cycle model exactly
and measure LN vs BN-affine vs folded on this host.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.bn import BatchNorm, bn_cycle_model, fold_bn_into_linear, ln_cycle_model
from repro import nn


def run() -> None:
    L = 128
    ln_c, bn_c = ln_cycle_model(L), bn_cycle_model(L)
    emit("fig9/cycle_model", 0.0,
         f"ln_cycles={ln_c} bn_cycles={bn_c} saving={1 - bn_c / ln_c:.3f} (paper 0.66)")

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 128, 64))
    w = jax.random.normal(key, (64, 64)) * 0.1
    lnp = nn.init_layernorm(64)
    bn = BatchNorm(64)
    bnp = bn.init()

    t_ln = time_fn(jax.jit(lambda a: nn.layernorm(lnp, a @ w)), x)
    t_bn = time_fn(jax.jit(lambda a: bn(bnp, a @ w)), x)
    w2, b2 = fold_bn_into_linear(w, None, bnp)
    t_fold = time_fn(jax.jit(lambda a: a @ w2 + b2), x)
    emit("fig9/host_timing", t_ln, f"ln={t_ln:.0f}us bn={t_bn:.0f}us bn_folded={t_fold:.0f}us")


if __name__ == "__main__":
    run()

"""Quantized-serving accuracy gate: the deploy path vs the fp32 reference.

The ROADMAP's "Quantized serving parity" item: the deploy compilation
(``repro.serve.deploy``: every BN folded, Pallas kernels in the hot spots,
weights pre-rounded onto the FP10 grid) must not silently degrade audio
quality. This benchmark first TRAINS the model for real on synthetic
speech+noise fixtures (``train.finetune_prune.train_dense``, ``--train-steps``
of the paper's Eq.-2 loss — quality numbers from a trained checkpoint, not a
BN-warmed random init) and then measures:

- **SI-SNR of each serving path against the fp32 ``enhance_offline``
  reference** — the parity number. fp32 paths sit at float-error level
  (>100 dB); the FP10 path lands wherever the deployment grid's ~2^-4
  relative mantissa step puts it (tens of dB), and THAT number is gated by
  ``--min-si-snr`` (exit 1 below it — the CI contract).
- PESQ of each path against the reference **when the optional ``pesq``
  package is installed** (it is not baked into the offline container);
  ``null`` in the JSON otherwise. The paper reports PESQ/STOI; SI-SNR is
  the always-available stand-in (docs/benchmarks.md).
- Enhancement quality (SI-SNR vs the clean signal) for context, so a path
  that "matches the reference" by doing nothing would still be visible.

Paths measured: ``stream-fp32`` (the streaming loop, THE streaming
invariant's other half), ``deploy-fp32`` (folded graph, Pallas kernels,
no quantization — folding is exact algebra), ``deploy-fp10`` (the paper's
deployment number format). The deploy paths are driven through a
``lax.scan`` over ``stream_hop_fused`` — the same state-carrying fused hop
the multi-hop dispatch path scans over.

Results go to stdout (CSV via benchmarks.common.emit) and
``BENCH_deploy_parity.json``. A threshold test version of this gate runs in
tier-1 (tests/test_deploy.py::test_fp10_deploy_si_snr_gate).

Run:  PYTHONPATH=src python benchmarks/deploy_parity.py [--seconds S]
          [--batch B] [--min-si-snr DB] [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from common import emit  # noqa: E402

from repro.audio.metrics import si_snr_db  # noqa: E402
from repro.audio.synthetic import batch_for_step  # noqa: E402
from repro.core.quant import FP10  # noqa: E402
from repro.launch.serve import reduced_cfg  # noqa: E402
from repro.models import tftnn as tft  # noqa: E402
from repro.serve.deploy import build_deploy_plan, stream_hop_fused  # noqa: E402
from repro.serve.streaming_se import (  # noqa: E402
    enhance_offline,
    enhance_streaming,
    init_stream,
)
from repro.train.finetune_prune import train_dense  # noqa: E402


def enhance_deploy(plan, params, wave: jax.Array) -> jax.Array:
    """Drive the fused deploy hop over whole utterances via lax.scan.

    ``params`` (the UNfolded tree) only sizes the initial recurrent state;
    the model math runs entirely on the plan's folded weights. This is the
    same scan-composes-with-``stream_hop_fused`` property the serving
    stack's multi-hop fused dispatch relies on.
    """
    B, S = wave.shape
    hop = plan.cfg.hop
    n = S // hop
    hops = wave[:, : n * hop].reshape(B, n, hop).transpose(1, 0, 2)
    st = init_stream(params, plan.cfg, B)

    def body(s, h):
        return stream_hop_fused(plan, s, h)

    _, outs = jax.lax.scan(body, st, hops)
    return outs.transpose(1, 0, 2).reshape(B, n * hop)


def _pesq_or_none(ref: np.ndarray, est: np.ndarray, sample_rate: int):
    """Mean PESQ when the optional ``pesq`` package exists, else None."""
    try:
        from pesq import pesq
    except ImportError:
        return None
    mode = "nb" if sample_rate < 16000 else "wb"
    scores = [
        pesq(sample_rate, np.asarray(r, np.float32), np.asarray(e, np.float32), mode)
        for r, e in zip(ref, est)
    ]
    return float(np.mean(scores))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Deploy-path accuracy gate: SI-SNR (and PESQ when "
        "available) of the folded/FP10 serving graphs vs the fp32 offline "
        "reference; exits 1 when the FP10 path drops below --min-si-snr."
    )
    ap.add_argument("--seconds", type=float, default=2.0,
                    help="seconds of synthetic audio per fixture utterance")
    ap.add_argument("--batch", type=int, default=4,
                    help="fixture utterances (averaged in the gate)")
    ap.add_argument("--min-si-snr", type=float, default=15.0,
                    help="minimum mean SI-SNR (dB) of the deploy-fp10 path "
                    "vs the fp32 offline reference; below this the gate "
                    "fails (measured headroom on the reduced config: "
                    "~25 dB)")
    ap.add_argument("--train-steps", type=int, default=24,
                    help="real training steps on synthetic fixtures before "
                    "measuring (train.finetune_prune.train_dense), so the "
                    "quality numbers come from a trained checkpoint, not a "
                    "BN-warmed random init")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fixtures (<=0.5s, batch<=2, 2 train "
                    "steps) so the interpret-mode kernels finish in seconds")
    ap.add_argument("--json", default="BENCH_deploy_parity.json",
                    help="where to write the machine-readable results")
    args = ap.parse_args()
    if args.smoke:
        args.seconds = min(args.seconds, 0.5)
        args.batch = min(args.batch, 2)
        args.train_steps = min(args.train_steps, 2)

    sample_rate = 8000
    cfg = reduced_cfg(tft.tftnn_config())
    params, train_losses = train_dense(
        cfg, steps=max(1, args.train_steps), batch=2, num_samples=2048, seed=0
    )
    print(f"# trained {len(train_losses)} steps: loss "
          f"{train_losses[0]:.4f} -> {train_losses[-1]:.4f}")
    samples = max(cfg.hop, int(args.seconds * sample_rate) // cfg.hop * cfg.hop)
    noisy, clean = batch_for_step(1, 0, batch=args.batch, num_samples=samples)
    noisy = jnp.asarray(noisy)

    ref = enhance_offline(params, cfg, noisy)  # fp32 reference (B, S')
    clean = np.asarray(clean)[:, : ref.shape[1]]

    paths = {
        "stream-fp32": lambda: enhance_streaming(params, cfg, noisy),
        "deploy-fp32": lambda: enhance_deploy(
            build_deploy_plan(params, cfg), params, noisy),
        "deploy-fp10": lambda: enhance_deploy(
            build_deploy_plan(params, cfg, quant=FP10), params, noisy),
    }

    result = {
        "benchmark": "deploy_parity",
        "config": {
            "seconds": args.seconds,
            "batch": args.batch,
            "samples": samples,
            "min_si_snr_db": args.min_si_snr,
            "train_steps": args.train_steps,
            "train_loss_first": train_losses[0],
            "train_loss_last": train_losses[-1],
            "smoke": args.smoke,
            "jax_backend": jax.default_backend(),
        },
        "points": [],
    }
    print("name,us_per_call,derived")
    ref_np = np.asarray(ref)
    for name, fn in paths.items():
        t0 = time.perf_counter()
        est = np.asarray(fn())[:, : ref.shape[1]]
        wall = time.perf_counter() - t0
        parity = float(jnp.mean(si_snr_db(jnp.asarray(est), ref)))
        quality = float(jnp.mean(si_snr_db(jnp.asarray(est), jnp.asarray(clean))))
        pesq_score = _pesq_or_none(ref_np, est, sample_rate)
        point = {
            "path": name,
            "si_snr_vs_ref_db": parity,
            "si_snr_vs_clean_db": quality,
            "pesq_vs_ref": pesq_score,
            "wall_s": wall,
        }
        result["points"].append(point)
        emit(
            f"path={name}",
            wall * 1e6,
            f"si_snr_vs_ref={parity:.2f}dB si_snr_vs_clean={quality:.2f}dB "
            f"pesq={'n/a' if pesq_score is None else f'{pesq_score:.2f}'}",
        )

    fp10 = next(p for p in result["points"] if p["path"] == "deploy-fp10")
    result["gate"] = {
        "path": "deploy-fp10",
        "si_snr_vs_ref_db": fp10["si_snr_vs_ref_db"],
        "min_si_snr_db": args.min_si_snr,
        "passed": fp10["si_snr_vs_ref_db"] >= args.min_si_snr,
    }
    out_path = Path(args.json)
    out_path.write_text(json.dumps(result, indent=2) + "\n", encoding="utf-8")
    print(f"# wrote {out_path} ({len(result['points'])} paths)")
    if not result["gate"]["passed"]:
        raise SystemExit(
            f"deploy-fp10 parity gate FAILED: SI-SNR "
            f"{fp10['si_snr_vs_ref_db']:.2f} dB < {args.min_si_snr:.2f} dB"
        )
    print(f"# gate passed: deploy-fp10 SI-SNR "
          f"{fp10['si_snr_vs_ref_db']:.2f} dB >= {args.min_si_snr:.2f} dB")


if __name__ == "__main__":
    main()

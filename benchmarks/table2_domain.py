"""Table II: mask/loss domain ablation (trained tiny models, synthetic data).

The paper's finding: with TF masking, the F-only loss collapses quality on
the compressed model (PESQ 2.6788 -> 2.1190), while the cross-domain T+F
loss recovers it (2.746). We reproduce the *ordering* with short training
runs of a reduced TFTNN on synthetic VoiceBank/UrbanSound stand-ins, scored
by SI-SNR / STOI-proxy (PESQ binaries unavailable offline — DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.audio.metrics import all_metrics
from repro.audio.synthetic import batch_for_step
from repro.models.tftnn import init_tft, tftnn_config
from repro.train.train_loop import TrainSettings, make_se_eval_step, make_se_train_step, make_train_state

STEPS = 60
BATCH = 4
SAMPLES = 8192


def _train(cfg, loss_domain: str, steps: int = STEPS, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    state = make_train_state(init_tft(key, cfg), TrainSettings())
    step = jax.jit(make_se_train_step(cfg, loss_domain=loss_domain))
    for i in range(steps):
        noisy, clean = batch_for_step(seed, i, batch=BATCH, num_samples=SAMPLES)
        state, m = step(state, noisy, clean)
    return state


def _score(cfg, state, seed: int = 999):
    ev = make_se_eval_step(cfg)
    noisy, clean = batch_for_step(seed, 0, batch=8, num_samples=SAMPLES)
    est = ev(state["params"], noisy)
    return {k: float(v) for k, v in all_metrics(est, clean).items()}


def run(steps: int = STEPS) -> None:
    cfg = dataclasses.replace(
        tftnn_config(), freq_bins=64, channels=16, att_dim=8, num_heads=1, gru_hidden=16,
        dilation_rates=(1, 2, 4),
    )
    for domain, tag in (("t+f", "TFmask+TFloss(Eq.2)"), ("f", "TFmask+Floss")):
        state = _train(cfg, domain, steps)
        s = _score(cfg, state)
        emit(f"table2/{tag}", 0.0,
             f"si_snr={s['si_snr']:.2f} stoi_proxy={s['stoi_proxy']:.3f} snr={s['snr']:.2f}")


if __name__ == "__main__":
    run()

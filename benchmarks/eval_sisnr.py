"""Batch SI-SNR over wav pairs — real enhancement-quality numbers for CI.

The pruning Pareto needs a quality axis that is measured on audio, not
proxied by parameter counts. This tool scores estimated/reference waveform
pairs with the repo's SI-SNR (and plain SNR) metrics, in the style of
aps's ``bin/compute_sisnr.py``: point it at a manifest (or two directories
paired by filename), get per-utterance scores plus the mean, machine-
readable.

Pair sources (exactly one):
- ``--manifest m.json`` — JSON list of ``{"est": path, "ref": path}``
  entries (a ``{"pairs": [...]}`` wrapper is also accepted);
- ``--est-dir D1 --ref-dir D2`` — files paired by basename;
- ``--fixture DIR`` — no audio on disk at all: synthesizes the repo's
  speech+noise fixtures (``repro.audio.synthetic``), writes noisy/clean
  wav pairs + a manifest into DIR, and scores noisy-vs-clean. That is the
  unenhanced baseline SI-SNR (~ the mixing SNR), and doubles as a wav
  round-trip check.

Outputs CSV rows (benchmarks.common.emit) and a JSON report (``--json``).
``eval_pairs``/``write_fixture`` are importable — benchmarks/prune_pareto.py
reuses them for its quality axis.

Run:  PYTHONPATH=src python benchmarks/eval_sisnr.py --fixture /tmp/fx
      PYTHONPATH=src python benchmarks/eval_sisnr.py --manifest pairs.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from common import emit  # noqa: E402

from repro.audio.metrics import si_snr_db, snr_db  # noqa: E402
from repro.audio.synthetic import batch_for_step  # noqa: E402
from repro.audio.wav import read_wav, write_wav  # noqa: E402

SAMPLE_RATE = 8000


def pair_si_snr(est: np.ndarray, ref: np.ndarray) -> Tuple[float, float]:
    """(si_snr_db, snr_db) of one utterance pair, truncated to equal length."""
    n = min(est.shape[-1], ref.shape[-1])
    e = jnp.asarray(est[..., :n], jnp.float32)
    r = jnp.asarray(ref[..., :n], jnp.float32)
    return float(jnp.mean(si_snr_db(e, r))), float(jnp.mean(snr_db(e, r)))


def eval_pairs(pairs: List[Dict[str, str]]) -> List[Dict]:
    """Score [{'est': path, 'ref': path}, ...] -> per-utterance results."""
    out = []
    for p in pairs:
        est, sr_e = read_wav(p["est"])
        ref, sr_r = read_wav(p["ref"])
        if sr_e != sr_r:
            raise ValueError(
                f"sample-rate mismatch: {p['est']} is {sr_e} Hz, "
                f"{p['ref']} is {sr_r} Hz"
            )
        si, sn = pair_si_snr(est, ref)
        out.append({"est": str(p["est"]), "ref": str(p["ref"]),
                    "si_snr_db": si, "snr_db": sn})
    return out


def write_fixture(
    directory: str,
    *,
    utts: int = 4,
    seconds: float = 1.0,
    seed: int = 7,
    snr_db_mix: float = 2.5,
) -> Path:
    """Write noisy/clean wav pairs + manifest.json into ``directory``.

    Returns the manifest path. The noisy files play the role of an
    (un)enhanced estimate; benchmarks swap in their own est files.
    """
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    samples = max(256, int(seconds * SAMPLE_RATE))
    noisy, clean = batch_for_step(
        seed, 0, batch=utts, num_samples=samples, snr_db=snr_db_mix
    )
    pairs = []
    for i in range(utts):
        est_p = d / f"noisy_{i:03d}.wav"
        ref_p = d / f"clean_{i:03d}.wav"
        write_wav(est_p, np.asarray(noisy[i]), SAMPLE_RATE)
        write_wav(ref_p, np.asarray(clean[i]), SAMPLE_RATE)
        pairs.append({"est": str(est_p), "ref": str(ref_p)})
    manifest = d / "manifest.json"
    manifest.write_text(json.dumps({"pairs": pairs}, indent=2) + "\n", "utf-8")
    return manifest


def _load_manifest(path: str) -> List[Dict[str, str]]:
    data = json.loads(Path(path).read_text("utf-8"))
    pairs = data["pairs"] if isinstance(data, dict) else data
    for p in pairs:
        if "est" not in p or "ref" not in p:
            raise ValueError(f"manifest entry missing est/ref keys: {p}")
    return pairs


def _pair_dirs(est_dir: str, ref_dir: str) -> List[Dict[str, str]]:
    est = {p.name: p for p in sorted(Path(est_dir).glob("*.wav"))}
    ref = {p.name: p for p in sorted(Path(ref_dir).glob("*.wav"))}
    names = sorted(est.keys() & ref.keys())
    if not names:
        raise SystemExit(f"no wav basenames shared by {est_dir} and {ref_dir}")
    return [{"est": str(est[n]), "ref": str(ref[n])} for n in names]


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Batch SI-SNR over est/ref wav pairs (manifest, paired "
        "directories, or a self-written synthetic fixture)."
    )
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--manifest", help="JSON list of {est, ref} wav pairs")
    src.add_argument("--est-dir", help="directory of estimate wavs "
                     "(paired with --ref-dir by basename)")
    src.add_argument("--fixture", metavar="DIR",
                     help="write a synthetic noisy/clean fixture into DIR "
                     "and score it (the unenhanced baseline)")
    ap.add_argument("--ref-dir", help="directory of reference wavs")
    ap.add_argument("--utts", type=int, default=4, help="fixture utterances")
    ap.add_argument("--seconds", type=float, default=1.0,
                    help="fixture utterance length")
    ap.add_argument("--seed", type=int, default=7, help="fixture seed")
    ap.add_argument("--json", default="BENCH_eval_sisnr.json",
                    help="where to write the JSON report")
    args = ap.parse_args()

    if args.manifest:
        pairs, source = _load_manifest(args.manifest), args.manifest
    elif args.est_dir:
        if not args.ref_dir:
            ap.error("--est-dir requires --ref-dir")
        pairs = _pair_dirs(args.est_dir, args.ref_dir)
        source = f"{args.est_dir} vs {args.ref_dir}"
    else:
        manifest = write_fixture(
            args.fixture, utts=args.utts, seconds=args.seconds, seed=args.seed
        )
        pairs, source = _load_manifest(str(manifest)), str(manifest)

    utt_results = eval_pairs(pairs)
    print("name,us_per_call,derived")
    for r in utt_results:
        emit(
            f"utt={Path(r['est']).name}", 0.0,
            f"si_snr={r['si_snr_db']:.2f}dB snr={r['snr_db']:.2f}dB",
        )
    mean_si = float(np.mean([r["si_snr_db"] for r in utt_results]))
    mean_sn = float(np.mean([r["snr_db"] for r in utt_results]))
    report = {
        "benchmark": "eval_sisnr",
        "source": source,
        "num_utts": len(utt_results),
        "mean_si_snr_db": mean_si,
        "mean_snr_db": mean_sn,
        "utts": utt_results,
    }
    Path(args.json).write_text(json.dumps(report, indent=2) + "\n", "utf-8")
    emit("mean", 0.0, f"si_snr={mean_si:.2f}dB snr={mean_sn:.2f}dB")
    print(f"# wrote {args.json} ({len(utt_results)} utterances)")


if __name__ == "__main__":
    main()

"""§IV-A real-time accounting: MMAC/frame vs the 16-MAC @ 62.5 MHz budget."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.streaming import RealTimeBudget
from repro.models.tftnn import macs_per_frame, tftnn_config, tstnn_config


def run() -> None:
    budget = RealTimeBudget()
    emit("realtime/required_clock", 0.0,
         f"paper_workload=15.86MMAC/frame -> clock={budget.required_clock_hz / 1e6:.1f}MHz (paper 62.5)")
    for cfg in (tftnn_config(), tstnn_config()):
        mf = macs_per_frame(cfg) / 1e6
        ok = budget.real_time_ok(mf * 1e6, clock_hz=62.5e6, num_macs=16)
        emit(f"realtime/{cfg.name}", 0.0, f"mmac_per_frame={mf:.2f} fits_16MAC@62.5MHz={ok}")


if __name__ == "__main__":
    run()
